//! Integration tests for the sharded store subsystem
//! (`rust/src/shardstore/`): two-tier admission end to end (the hot
//! shard sheds with `ERR OVERLOAD shard=<i>` while its siblings admit),
//! routing and staleness-composition properties, and the aggregated
//! linearizability monitor over seeded multi-shard interleavings.

use std::sync::Arc;
use std::time::Duration;

use concurrent_size::cli::PolicyKind;
use concurrent_size::harness::{client_swarm, SwarmConfig};
use concurrent_size::history::monitor::ShardedMonitor;
use concurrent_size::prop_assert;
use concurrent_size::proptest_lite;
use concurrent_size::server::{BlockingClient, Server, ServerConfig, Watermarks};
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::shardstore::{make_shard_store, route, ShardStore};
use concurrent_size::size::{LinearizableSize, SizeOpts};
use concurrent_size::workload::{KeyDist, UPDATE_HEAVY};
use concurrent_size::MAX_THREADS;

const SHARDS: usize = 4;

/// A 4-shard linearizable store behind the `ConcurrentSet` face, as the
/// server mounts it.
fn shard_store() -> Arc<dyn ConcurrentSet> {
    let opts = SizeOpts::default().with_shards(2);
    Arc::from(make_shard_store(PolicyKind::Linearizable, SHARDS, 1 << 12, opts).unwrap())
}

/// The first `n` keys that [`route`] sends to `shard` (deterministic:
/// routing is a pure function, so tests and the reactor always agree).
fn keys_for_shard(shard: usize, n: usize) -> Vec<u64> {
    (1u64..).filter(|&k| route(k, SHARDS) == shard).take(n).collect()
}

/// Tier-2 admission end to end: fill exactly one routed shard past its
/// watermark — it sheds with `ERR OVERLOAD shard=<i>` while a sibling
/// shard keeps admitting and the *global* size surfaces stay accurate —
/// then drain through the hysteresis band and readmit at the low mark.
#[test]
fn hot_shard_sheds_while_siblings_admit_and_global_size_stays_accurate() {
    let config = ServerConfig {
        handlers: 2,
        shard_admission: Some(Watermarks::new(20, 10)),
        ..Default::default()
    };
    let server = Server::bind("127.0.0.1:0", shard_store(), config).expect("bind");
    let mut client = BlockingClient::connect(server.local_addr());

    // Drive 40 PUTs that all route to shard `hot`: the first 20 admit
    // (the gate reads the shard estimate before each insert), everything
    // past the high watermark sheds with the shard-tagged reply.
    let hot = 2usize;
    let hot_keys = keys_for_shard(hot, 40);
    let shard_reply = format!("ERR OVERLOAD shard={hot}");
    for (i, &k) in hot_keys.iter().enumerate() {
        let want = if i < 20 { "1" } else { shard_reply.as_str() };
        assert_eq!(client.cmd(format!("PUT {k}")), want, "hot PUT #{i}");
    }

    // Siblings are untouched by the hot shard's gate.
    let sibling_key = keys_for_shard((hot + 1) % SHARDS, 1)[0];
    assert_eq!(
        client.cmd(format!("PUT {sibling_key}")),
        "1",
        "sibling must admit"
    );

    // Global SIZE (aggregated exact) and SIZE? (summed mirrors) both see
    // exactly the admitted census — sheds never reached any shard.
    assert_eq!(client.cmd("SIZE"), "21");
    assert_eq!(client.cmd("SIZE?"), "21");
    let stats = concurrent_size::server::parse_stats(&client.cmd("STATS")).expect("STATS");
    assert_eq!(stats["store_shards"], SHARDS as u64);
    assert_eq!(stats["shard_shed"], 20);
    assert_eq!(
        stats["shed"],
        0,
        "the global tier is off; only the shard tier shed"
    );

    // Hysteresis: drain the hot shard into the band (estimate 15) — DELs
    // always admit, PUTs on the hot shard stay shed.
    for &k in &hot_keys[..5] {
        assert_eq!(client.cmd(format!("DEL {k}")), "1");
    }
    assert_eq!(
        client.cmd(format!("PUT {}", hot_keys[39])),
        shard_reply,
        "band stays shedding"
    );

    // Drain to the low watermark: the hot shard readmits.
    for &k in &hot_keys[5..10] {
        assert_eq!(client.cmd(format!("DEL {k}")), "1");
    }
    assert_eq!(
        client.cmd(format!("PUT {}", hot_keys[39])),
        "1",
        "readmit at the low mark"
    );
    assert_eq!(client.cmd("SIZE"), "12");
}

/// A zipfian swarm against per-shard watermarks: the skewed shard trips
/// its gate (sheds observed by clients and counted in STATS as
/// `shard_shed`, never as global `shed`), while enough sibling capacity
/// admits that the final census exceeds any single shard's high mark —
/// and the aggregated size surfaces agree at quiescence.
#[test]
fn zipf_swarm_overloads_the_hot_shard_but_not_the_store() {
    let store = shard_store();
    let config = ServerConfig {
        handlers: 2,
        shard_admission: Some(Watermarks::new(24, 12)),
        ..Default::default()
    };
    let server = Server::bind("127.0.0.1:0", store.clone(), config).expect("bind");
    let swarm = client_swarm(
        server.local_addr(),
        SwarmConfig {
            key_dist: KeyDist::Zipf(0.99),
            ..SwarmConfig::new(8, 600, UPDATE_HEAVY, 4096, 0x51AB5)
        },
    )
    .expect("zipf swarm");
    assert_eq!(swarm.ops, 8 * 600);
    assert_eq!(swarm.errors, 0, "sheds are not protocol errors");
    assert!(
        swarm.overloads > 0,
        "zipf skew never tripped a shard watermark"
    );

    let mut probe = BlockingClient::connect(server.local_addr());
    let stats = concurrent_size::server::parse_stats(&probe.cmd("STATS")).expect("STATS");
    assert_eq!(
        stats["shard_shed"],
        swarm.overloads,
        "every shed was shard-tier"
    );
    assert_eq!(stats["shed"], 0, "the global gate never fired");

    // Quiescent accuracy across both global read paths, and cross-checked
    // against the store's own quiescent census.
    let exact: i64 = probe.cmd("SIZE").parse().expect("numeric SIZE");
    let estimate: i64 = probe.cmd("SIZE?").parse().expect("numeric SIZE?");
    assert_eq!(
        exact,
        estimate,
        "aggregated exact vs summed mirrors at quiescence"
    );
    assert_eq!(Some(exact), store.size_estimate());
    assert!(
        exact > 24,
        "census {exact} within one shard's watermark — siblings never admitted"
    );
}

/// Routing properties: total (every key answers, in range) and stable
/// (pure function of `(key, shards)` — no per-call or per-site state).
#[test]
fn route_is_total_and_stable_under_random_probing() {
    proptest_lite::run("route is total and stable", |rng| {
        let shards = 1 + rng.gen_range(64) as usize;
        for _ in 0..200 {
            let key = rng.next_u64();
            let first = route(key, shards);
            prop_assert!(
                first < shards,
                "route({key}, {shards}) = {first} out of range"
            );
            prop_assert!(
                route(key, shards) == first,
                "route({key}, {shards}) unstable"
            );
        }
        Ok(())
    });
}

/// The composed staleness contract: whatever the shard count, occupancy
/// and bound, `global_recent(d)` reports `age = max(per-shard ages) <= d`
/// and (at quiescence) the exact census.
#[test]
fn global_recent_age_never_exceeds_the_requested_bound() {
    proptest_lite::run("global_recent composes the staleness bound", |rng| {
        let shards = 1 + rng.gen_range(6) as usize;
        let store: ShardStore<LinearizableSize> = ShardStore::new(
            MAX_THREADS,
            shards,
            1 << 8,
            SizeOpts::default().with_shards(2),
        );
        let mut live = 0i64;
        for _ in 0..rng.gen_range(150) {
            live += i64::from(store.insert(rng.gen_range(512)));
        }
        let bound = Duration::from_micros(1 + rng.gen_range(50_000));
        let view = store.size_recent(bound);
        let view = match view {
            Some(view) => view,
            None => return Err("recent view missing on a sized policy".into()),
        };
        prop_assert!(
            view.age <= bound,
            "composed age {:?} over the bound {bound:?} ({shards} shards)",
            view.age
        );
        prop_assert!(
            view.value == live,
            "recent value {} != live {live}",
            view.value
        );
        Ok(())
    });
}

/// The aggregated monitor across a seeded interleaving sweep: concurrent
/// per-shard updaters plus a global size reader (alternating exact and
/// bounded-staleness reads) must produce zero unjustified aggregated
/// sizes — on every seed.
#[test]
fn aggregated_monitor_justifies_every_global_size_across_seeds() {
    for seed in 0..12u64 {
        let store: Arc<ShardStore<LinearizableSize>> = Arc::new(ShardStore::new(
            MAX_THREADS,
            3,
            1 << 8,
            SizeOpts::default().with_shards(2),
        ));
        let monitor = Arc::new(ShardedMonitor::new(3));
        let mut workers = Vec::new();
        for t in 0..2u64 {
            let store = store.clone();
            let monitor = monitor.clone();
            workers.push(std::thread::spawn(move || {
                let mut rng = concurrent_size::rng::Xoshiro256::new(seed ^ (t << 32));
                for _ in 0..300 {
                    let key = 1 + rng.gen_range(96);
                    let timer = monitor.begin();
                    if rng.gen_bool(0.6) {
                        if store.insert(key) {
                            monitor.commit_update(route(key, 3), timer, 1);
                        }
                    } else if store.delete(key) {
                        monitor.commit_update(route(key, 3), timer, -1);
                    }
                }
            }));
        }
        {
            let store = store.clone();
            let monitor = monitor.clone();
            workers.push(std::thread::spawn(move || {
                let bound = Duration::from_millis(2);
                for i in 0..150 {
                    let timer = monitor.begin();
                    if i % 2 == 0 {
                        let view = store.aggregator().global_exact().expect("exact view");
                        monitor.commit_size(timer, view.value);
                    } else {
                        let view = store.aggregator().global_recent(bound).expect("recent view");
                        // A recent reading may predate its invocation by
                        // up to its composed age: widen the window.
                        monitor.commit_size_with_slack(timer, view.value, view.age);
                    }
                }
            }));
        }
        for worker in workers {
            worker.join().expect("monitor worker panicked");
        }
        let report = monitor.verify();
        assert!(
            report.is_ok(),
            "seed {seed}: unjustified aggregated sizes: {:?}",
            report.violations
        );
        assert!(
            report.sizes_checked >= 150,
            "seed {seed}: reader under-recorded"
        );
    }
}
