//! Integration tests for the combining size arbiter (`size_exact`) and
//! the published bounded-staleness reads (`size_recent`) across all four
//! structures and all six policies, plus the `OptimisticSize`
//! retry-budget sweep.

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering::SeqCst};
use std::sync::Arc;
use std::time::Duration;

use concurrent_size::bench_util::{make_set, STRUCTURES};
use concurrent_size::cli::PolicyKind;
use concurrent_size::hashtable::HashTableSet;
use concurrent_size::history::{self, DeltaLog};
use concurrent_size::prop_assert;
use concurrent_size::proptest_lite;
use concurrent_size::rng::Xoshiro256;
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::size::{HandshakeSize, OpKind, OptimisticSize, SizeOpts, SizePolicy};
use concurrent_size::MAX_THREADS;

const NEW_POLICIES: [PolicyKind; 2] = [PolicyKind::Handshake, PolicyKind::Optimistic];

/// The PR's headline claim: N threads hammering `size_exact()` on the
/// handshake policy share combine rounds, so the handshake count grows by
/// one per *batch* — strictly fewer handshakes than calls — instead of
/// one per call as with raw serialized `size()`.
#[test]
fn combining_batches_handshakes_below_call_count() {
    let set = Arc::new(HashTableSet::<HandshakeSize>::new(MAX_THREADS, 256));
    for k in 1..=40u64 {
        set.insert(k);
    }
    // Dwell long enough that the hammering threads must overlap a round
    // even on a single-core box (the sleep yields the core to them).
    set.arbiter().set_combine_window(Duration::from_micros(800));
    const THREADS: u64 = 4;
    const CALLS: u64 = 25;
    let handles: Vec<_> = (0..THREADS)
        .map(|_| {
            let set = set.clone();
            std::thread::spawn(move || {
                for _ in 0..CALLS {
                    let v = set.size_exact().expect("handshake provides size");
                    assert_eq!(v.value, 40);
                    assert_eq!(v.age, Duration::ZERO, "exact reads are fresh");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let total = THREADS * CALLS;
    let handshakes = set.policy().handshake_count();
    let stats = set.size_stats().unwrap();
    assert_eq!(
        handshakes, stats.rounds,
        "every combine round is exactly one handshake"
    );
    assert!(
        handshakes < total,
        "no combining: {handshakes} handshakes for {total} size_exact calls"
    );
    assert!(stats.adoptions > 0, "no call ever shared a round");
    assert_eq!(stats.rounds + stats.adoptions, total);
}

/// `size_recent` within the staleness bound is a published read: no
/// handshake flag is raised (the handshake count stays frozen) and no new
/// arbiter round starts.
#[test]
fn recent_reads_raise_no_handshake_flag() {
    let set = HashTableSet::<HandshakeSize>::new(MAX_THREADS, 256);
    for k in 1..=17u64 {
        set.insert(k);
    }
    let exact = set.size_exact().unwrap();
    assert_eq!(exact.value, 17);
    let h0 = set.policy().handshake_count();
    let rounds0 = set.size_stats().unwrap().rounds;
    for _ in 0..200 {
        let v = set.size_recent(Duration::from_secs(600)).unwrap();
        assert_eq!(v.value, 17);
        assert!(v.shared);
        assert!(v.age <= Duration::from_secs(600));
    }
    assert_eq!(
        set.policy().handshake_count(),
        h0,
        "size_recent hit must not raise the handshake flag"
    );
    assert_eq!(set.size_stats().unwrap().rounds, rounds0);
    assert_eq!(set.size_stats().unwrap().recent_hits, 200);
}

/// A published result older than the bound forces a fresh combine round,
/// which observes updates made since the last publish.
#[test]
fn recent_refreshes_once_stale() {
    let set = HashTableSet::<HandshakeSize>::new(MAX_THREADS, 64);
    set.insert(1);
    assert_eq!(set.size_exact().unwrap().value, 1);
    set.insert(2);
    std::thread::sleep(Duration::from_millis(5));
    let v = set.size_recent(Duration::from_millis(1)).unwrap();
    assert_eq!(v.value, 2, "stale publish must be refreshed");
    assert_eq!(v.age, Duration::ZERO);
    assert_eq!(set.size_stats().unwrap().recent_refreshes, 1);
}

/// `size_exact` keeps today's linearizable semantics under combining: a
/// single recording mutator's DeltaLog must stay legal, its checkpoints
/// must match `size_exact` exactly, and racing `size_exact` threads must
/// never observe an out-of-bounds value — on all four structures, for
/// both optimized policies.
#[test]
fn exact_history_linearizable_under_combining() {
    for structure in STRUCTURES {
        for policy in NEW_POLICIES {
            let set: Arc<dyn ConcurrentSet> =
                Arc::from(make_set(structure, policy, 256).unwrap());
            let log = DeltaLog::new();
            let key_space = 64i64;
            let stop = Arc::new(AtomicBool::new(false));
            let min_seen = Arc::new(AtomicI64::new(i64::MAX));
            let exact_calls = Arc::new(AtomicU64::new(0));

            std::thread::scope(|scope| {
                for _ in 0..2 {
                    let set = set.clone();
                    let stop = stop.clone();
                    let min_seen = min_seen.clone();
                    let exact_calls = exact_calls.clone();
                    scope.spawn(move || {
                        while !stop.load(SeqCst) {
                            let v = set.size_exact().unwrap();
                            exact_calls.fetch_add(1, SeqCst);
                            min_seen.fetch_min(v.value, SeqCst);
                            assert!(
                                (0..=key_space).contains(&v.value),
                                "size {} out of [0, {key_space}]",
                                v.value
                            );
                            std::thread::sleep(Duration::from_micros(200));
                        }
                    });
                }

                let mut rng = Xoshiro256::new(31 + policy as u64);
                let mut net = 0i64;
                for step in 0..3000 {
                    let k = rng.gen_range_incl(1, key_space as u64);
                    if rng.gen_bool(0.5) {
                        if set.insert(k) {
                            log.record_insert();
                            net += 1;
                        }
                    } else if set.delete(k) {
                        log.record_delete();
                        net -= 1;
                    }
                    if step % 128 == 0 {
                        // Only updater ⇒ the exact running size is forced.
                        assert_eq!(
                            set.size_exact().map(|v| v.value),
                            Some(net),
                            "{structure}/{policy:?} checkpoint at step {step}"
                        );
                    }
                }
                stop.store(true, SeqCst);
            });

            let (running, stats) = history::validate(&log.snapshot());
            assert!(
                stats.is_legal(),
                "{structure}/{policy:?}: illegal history {stats:?}"
            );
            assert_eq!(
                Some(stats.final_size),
                set.size_exact().map(|v| v.value),
                "{structure}/{policy:?}: log final vs size_exact()"
            );
            assert_eq!(running.last().copied().unwrap_or(0), stats.final_size);
            assert!(
                min_seen.load(SeqCst) >= 0,
                "{structure}/{policy:?}: concurrent size_exact saw negative"
            );
            let arb = set.size_stats().unwrap();
            assert!(
                arb.rounds <= exact_calls.load(SeqCst) + 3000 / 128 + 4,
                "{structure}/{policy:?}: more rounds than exact calls"
            );
        }
    }
}

/// Staleness-bound property: with a single mutator, `size_recent` either
/// hits the published result — whose value is exactly the size at the
/// last publish and whose age respects the bound — or refreshes to the
/// exact current size with age zero.
#[test]
fn prop_recent_respects_staleness_contract() {
    proptest_lite::run_with(
        "size_recent staleness contract",
        proptest_lite::Config {
            cases: 4,
            seed: 0xA3B1,
        },
        |rng| {
            for structure in STRUCTURES {
                for policy in NEW_POLICIES {
                    let set = make_set(structure, policy, 128).unwrap();
                    let mut net = 0i64;
                    let mut published = None::<i64>;
                    let key_space = 1 + rng.gen_range(40);
                    for _ in 0..(150 + rng.gen_range(250)) {
                        let k = rng.gen_range_incl(1, key_space);
                        match rng.gen_range(6) {
                            0 | 1 => {
                                if set.insert(k) {
                                    net += 1;
                                }
                            }
                            2 => {
                                if set.delete(k) {
                                    net -= 1;
                                }
                            }
                            3 => {
                                let v = set.size_exact().unwrap();
                                prop_assert!(
                                    v.value == net,
                                    "{structure}/{policy:?}: exact {} != net {net}",
                                    v.value
                                );
                                published = Some(net);
                            }
                            4 => {
                                // Generous bound: must hit the published
                                // value, or (before any publish) refresh.
                                let bound = Duration::from_secs(3600);
                                let v = set.size_recent(bound).unwrap();
                                prop_assert!(v.age <= bound, "age above bound");
                                match published {
                                    Some(p) => prop_assert!(
                                        v.value == p,
                                        "{structure}/{policy:?}: recent {} != published {p}",
                                        v.value
                                    ),
                                    None => {
                                        prop_assert!(
                                            v.value == net && v.age == Duration::ZERO,
                                            "unpublished recent must refresh exactly"
                                        );
                                        published = Some(net);
                                    }
                                }
                            }
                            _ => {
                                // Zero bound: always refreshes to exact.
                                let v = set.size_recent(Duration::ZERO).unwrap();
                                prop_assert!(
                                    v.value == net && v.age == Duration::ZERO,
                                    "{structure}/{policy:?}: zero-staleness recent \
                                     {} != net {net}",
                                    v.value
                                );
                                published = Some(net);
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// The freshness API answers coherently for every structure × policy:
/// `None` exactly when the policy is size-less, values agreeing with the
/// raw `size()` at quiescence, and arbiter stats exposed on all four
/// transformable structures.
#[test]
fn freshness_api_covers_all_structures_and_policies() {
    for structure in STRUCTURES {
        for policy in PolicyKind::ALL {
            let set = make_set(structure, policy, 64).unwrap();
            for k in 1..=9u64 {
                set.insert(k);
            }
            assert!(
                set.size_stats().is_some(),
                "{structure}/{policy:?}: arbiter stats missing"
            );
            if policy.provides_size() {
                let exact = set.size_exact().unwrap();
                assert_eq!(exact.value, 9, "{structure}/{policy:?}");
                assert!(exact.round > 0, "arbiter must stamp rounds");
                let recent = set.size_recent(Duration::from_secs(60)).unwrap();
                assert_eq!(recent.value, 9, "{structure}/{policy:?}");
                assert_eq!(set.size(), Some(9), "{structure}/{policy:?}");
            } else {
                assert_eq!(set.size_exact(), None, "{structure}/{policy:?}");
                assert_eq!(
                    set.size_recent(Duration::from_millis(1)),
                    None,
                    "{structure}/{policy:?}"
                );
            }
        }
    }
}

/// `OptimisticSize` retry-budget sweep: under churn the fallback counter
/// stays sane for every budget — it never exceeds the number of size
/// calls, a zero budget falls back on *every* call, and quiescent collects
/// never fall back on any positive budget.
#[test]
fn optimistic_retry_budget_sweep() {
    for retries in [0usize, 1, 2, 8, 32] {
        let p = Arc::new(OptimisticSize::with_max_retries(
            8,
            SizeOpts::default(),
            retries,
        ));
        assert_eq!(p.max_retries(), retries);
        let stop = Arc::new(AtomicBool::new(false));
        let churners: Vec<_> = (0..3usize)
            .map(|t| {
                let p = p.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    // Per-thread legal (insert-then-delete) histories,
                    // driven straight into the calculator.
                    let mut c = 0u64;
                    while !stop.load(SeqCst) {
                        c += 1;
                        let i = concurrent_size::size::UpdateInfo { tid: t, counter: c }.pack();
                        let calc = p.calculator().unwrap();
                        calc.update_metadata(i, OpKind::Insert);
                        calc.update_metadata(i, OpKind::Delete);
                    }
                })
            })
            .collect();
        const SIZES: u64 = 800;
        for _ in 0..SIZES {
            let s = p.size().unwrap();
            assert!(
                (0..=3).contains(&s),
                "budget {retries}: non-linearizable size {s}"
            );
        }
        stop.store(true, SeqCst);
        for c in churners {
            c.join().unwrap();
        }
        let fallbacks = p.fallback_count();
        assert!(
            fallbacks <= SIZES,
            "budget {retries}: {fallbacks} fallbacks for {SIZES} calls"
        );
        if retries == 0 {
            assert_eq!(
                fallbacks, SIZES,
                "a zero budget must take the wait-free path every call"
            );
        }
        // Quiescent collects succeed on the first double-collect for any
        // positive budget: the counter must stop moving.
        let quiesced = p.fallback_count();
        assert_eq!(p.size(), Some(0));
        if retries > 0 {
            assert_eq!(p.fallback_count(), quiesced, "quiescent collect fell back");
        }
    }
}
