//! Linearizability tests for the optimized size methods (`HandshakeSize`,
//! `OptimisticSize`) on all four structures, via the `history` checker:
//! recorded update histories must be legal (`history::validate`), `size()`
//! must track the running size exactly where the recording stream is the
//! linearization order, and the paper's Figure 1/2 anomaly probes must
//! never fire.

use std::sync::atomic::{AtomicBool, AtomicI64, Ordering::SeqCst};
use std::sync::Arc;

use concurrent_size::bench_util::{fig1_anomalies, fig2_anomalies, make_set, STRUCTURES};
use concurrent_size::cli::PolicyKind;
use concurrent_size::history::{self, DeltaLog};
use concurrent_size::proptest_lite;
use concurrent_size::rng::Xoshiro256;
use concurrent_size::set_api::ConcurrentSet;
use concurrent_size::prop_assert;

const NEW_POLICIES: [PolicyKind; 2] = [PolicyKind::Handshake, PolicyKind::Optimistic];

fn combos() -> impl Iterator<Item = (&'static str, PolicyKind)> {
    STRUCTURES
        .into_iter()
        .flat_map(|s| NEW_POLICIES.into_iter().map(move |p| (s, p)))
}

/// Sequential oracle: with one thread, linearizability degenerates to
/// sequential correctness — `size()` must equal a `BTreeSet` model at
/// every checkpoint, on every structure, for both new policies.
#[test]
fn sequential_model_all_structures() {
    for (structure, policy) in combos() {
        let set = make_set(structure, policy, 512).unwrap();
        let mut model = std::collections::BTreeSet::new();
        let mut rng = Xoshiro256::new(0x517E);
        for step in 0..3000 {
            let k = rng.gen_range_incl(1, 200);
            match rng.gen_range(3) {
                0 => assert_eq!(
                    set.insert(k),
                    model.insert(k),
                    "{structure}/{policy:?} insert {k}"
                ),
                1 => assert_eq!(
                    set.delete(k),
                    model.remove(&k),
                    "{structure}/{policy:?} delete {k}"
                ),
                _ => assert_eq!(
                    set.contains(k),
                    model.contains(&k),
                    "{structure}/{policy:?} contains {k}"
                ),
            }
            if step % 97 == 0 {
                assert_eq!(
                    set.size(),
                    Some(model.len() as i64),
                    "{structure}/{policy:?} size at step {step}"
                );
            }
        }
        assert_eq!(
            set.size(),
            Some(model.len() as i64),
            "{structure}/{policy:?}"
        );
    }
}

/// DeltaLog history check under concurrent `size()`: a single mutator
/// records its committed updates (its commit order IS the linearization
/// order, since it is the only updater), checkpoints `size()` against the
/// running sum, and a racing size thread asserts every observation stays
/// in bounds. Afterwards `history::validate` must call the log legal and
/// its final size must match the structure.
#[test]
fn delta_log_history_legal_under_concurrent_size() {
    for (structure, policy) in combos() {
        let set: Arc<dyn ConcurrentSet> = Arc::from(make_set(structure, policy, 256).unwrap());
        let log = DeltaLog::new();
        let key_space = 64i64;
        let stop = Arc::new(AtomicBool::new(false));
        let min_seen = Arc::new(AtomicI64::new(i64::MAX));

        std::thread::scope(|scope| {
            // Racing size observers (2 threads: exercises size-size
            // contention too — the handshake mutex, the optimistic
            // double-collect).
            for _ in 0..2 {
                let set = set.clone();
                let stop = stop.clone();
                let min_seen = min_seen.clone();
                scope.spawn(move || {
                    while !stop.load(SeqCst) {
                        let s = set.size().unwrap();
                        min_seen.fetch_min(s, SeqCst);
                        assert!(
                            (0..=key_space).contains(&s),
                            "size {s} out of [0, {key_space}]"
                        );
                        // Throttle: periodic (not saturating) sizes — the
                        // handshake method's intended regime, and it keeps
                        // the mutator from starving on single-core boxes.
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                });
            }

            // The single mutator: every successful update goes to the log.
            let mut rng = Xoshiro256::new(7 + policy as u64);
            let mut net = 0i64;
            for step in 0..4000 {
                let k = rng.gen_range_incl(1, key_space as u64);
                if rng.gen_bool(0.5) {
                    if set.insert(k) {
                        log.record_insert();
                        net += 1;
                    }
                } else if set.delete(k) {
                    log.record_delete();
                    net -= 1;
                }
                if step % 128 == 0 {
                    // Only updater ⇒ the exact running size is forced.
                    assert_eq!(
                        set.size(),
                        Some(net),
                        "{structure}/{policy:?} checkpoint at step {step}"
                    );
                }
            }
            stop.store(true, SeqCst);
        });

        let (running, stats) = history::validate(&log.snapshot());
        assert!(
            stats.is_legal(),
            "{structure}/{policy:?}: illegal history {stats:?}"
        );
        assert_eq!(
            Some(stats.final_size),
            set.size(),
            "{structure}/{policy:?}: log final vs size()"
        );
        assert_eq!(running.last().copied().unwrap_or(0), stats.final_size);
        assert!(
            min_seen.load(SeqCst) >= 0,
            "{structure}/{policy:?}: concurrent size saw negative"
        );
    }
}

/// Multi-writer churn: sizes stay within the live-key bound throughout and
/// match a membership census at quiescence.
#[test]
fn concurrent_churn_bounds_and_quiescent_exactness() {
    for (structure, policy) in combos() {
        let set: Arc<dyn ConcurrentSet> = Arc::from(make_set(structure, policy, 256).unwrap());
        let key_space = 96u64;
        let stop = Arc::new(AtomicBool::new(false));
        std::thread::scope(|scope| {
            for t in 0..3u64 {
                let set = set.clone();
                let stop = stop.clone();
                scope.spawn(move || {
                    let mut rng = Xoshiro256::new(t + 1);
                    while !stop.load(SeqCst) {
                        let k = rng.gen_range_incl(1, key_space);
                        if rng.gen_bool(0.5) {
                            set.insert(k);
                        } else {
                            set.delete(k);
                        }
                    }
                });
            }
            for _ in 0..150 {
                let s = set.size().unwrap();
                assert!(
                    (0..=key_space as i64).contains(&s),
                    "{structure}/{policy:?}: size {s} outside [0, {key_space}]"
                );
            }
            stop.store(true, SeqCst);
        });
        let live = (1..=key_space).filter(|&k| set.contains(k)).count();
        assert_eq!(
            set.size(),
            Some(live as i64),
            "{structure}/{policy:?} quiescent census"
        );
    }
}

/// The paper's anomaly probes must stay silent: no Figure 1
/// (contains=true then size=0) and no Figure 2 (negative size) schedules
/// on either new policy, on any structure.
#[test]
fn no_fig1_fig2_anomalies_on_new_policies() {
    for (structure, policy) in combos() {
        let set = make_set(structure, policy, 1024).unwrap();
        assert_eq!(
            fig1_anomalies(set.as_ref(), 150),
            0,
            "{structure}/{policy:?} exhibited the Figure 1 anomaly"
        );
        assert_eq!(
            fig2_anomalies(set.as_ref(), 50),
            0,
            "{structure}/{policy:?} exhibited the Figure 2 anomaly"
        );
    }
}

/// Property: random single-mutator workloads with interleaved size calls
/// leave a `history::validate`-legal delta log whose running size tracks
/// `size()` exactly, for both new policies on all four structures.
#[test]
fn prop_running_sizes_legal_on_all_structures() {
    proptest_lite::run_with(
        "new-policy histories legal",
        proptest_lite::Config {
            cases: 6,
            seed: 0x6A5D,
        },
        |rng| {
            for (structure, policy) in combos() {
                let set = make_set(structure, policy, 128).unwrap();
                let log = DeltaLog::new();
                let key_space = 1 + rng.gen_range(48);
                let mut net = 0i64;
                for _ in 0..(200 + rng.gen_range(400)) {
                    let k = rng.gen_range_incl(1, key_space);
                    match rng.gen_range(4) {
                        0 | 1 => {
                            if set.insert(k) {
                                log.record_insert();
                                net += 1;
                            }
                        }
                        2 => {
                            if set.delete(k) {
                                log.record_delete();
                                net -= 1;
                            }
                        }
                        _ => {
                            let s = set.size().unwrap();
                            prop_assert!(
                                s == net,
                                "{structure}/{policy:?}: size {s} != running {net}"
                            );
                        }
                    }
                }
                let (running, stats) = history::validate(&log.snapshot());
                prop_assert!(
                    stats.is_legal(),
                    "{structure}/{policy:?}: illegal history {stats:?}"
                );
                prop_assert!(
                    running.last().copied().unwrap_or(0) == net,
                    "{structure}/{policy:?}: log lost updates"
                );
                prop_assert!(
                    set.size() == Some(net),
                    "{structure}/{policy:?}: final size mismatch"
                );
            }
            Ok(())
        },
    );
}
