#!/usr/bin/env python3
"""Throughput regression gate for BENCH_ablation.json (CI: `make regress-check`).

Usage: check_ablation_regress.py BASELINE FRESH

Compares a freshly generated ablation report against the previous CI
run's artifact. Records are matched on their sweep identity — every
axis the bench varies — and a matched record regresses when its fresh
`workload_ops_per_sec` drops more than the scenario's threshold below
the baseline (25% unless SCENARIO_MAX_DROP says otherwise: noisy
socket-path scenarios can be granted more slack per scenario instead of
loosening the gate globally, and the quiet in-process scenarios run
tighter). Every run prints each scenario's worst observed drop against
its threshold, so tightening stays data-driven: a scenario whose margin
is consistently wide across CI runs is a tightening candidate.

Soft-fail semantics, by design:

* missing baseline file  -> warn + exit 0 (first run, or artifact
  download failed — CI marks that step continue-on-error);
* unreadable/garbage baseline -> warn + exit 0 (never let a stale
  artifact brick the pipeline — the schema gate guards the fresh file);
* baseline records with zero/absent throughput, or fresh records with
  no baseline counterpart (new sweep axes) -> skipped, reported.

Only a genuine >25% drop on a matched, previously-positive record
exits 1. Stdlib only.
"""

import json
import sys

# Identity axes: everything the sweeps are keyed on, nothing measured.
# (`final_buckets`/`migration_quanta`/`growth_windows` are measurements,
# not axes — only the starting bucket count identifies a growth cell.)
MATCH_KEYS = (
    "scenario",
    "policy",
    "mix",
    "size_call",
    "size_threads",
    "shards",
    "key_dist",
    "refresh_us",
    "reactors",
    "pipeline_depth",
    "scan_frac",
    "scan_span",
    "initial_buckets",
)
# Axis values assumed when a baseline record predates the axis, so old
# artifacts keep matching new reports (the recorder writes these exact
# defaults for scenarios that don't sweep the axis).
AXIS_DEFAULTS = {
    "scan_frac": 0.0,
    "scan_span": 0,
    "initial_buckets": 0,
}
MAX_DROP = 0.25
# Per-scenario overrides of MAX_DROP. The in-process sweeps (thread
# scaling only, no sockets) run tighter than the blanket; the scale
# sweeps run whole servers or shard fleets per cell, so their
# run-to-run noise is wider; scan_scale is the noisiest of all (socket
# path plus multi-line reply coalescing); resize_scale's windows are
# short by construction (a fixed op-count slice of one growth phase),
# so its mean rides scheduler noise. Tuning one of these is a one-line
# diff instead of a global loosening — use the per-scenario margin
# lines this script prints to decide when a threshold has headroom.
SCENARIO_MAX_DROP = {
    "periodic-size": 0.20,
    "size-heavy": 0.20,
    "shard_scale": 0.28,
    "reactor_scale": 0.30,
    "scan_scale": 0.40,
    "resize_scale": 0.40,
}


def max_drop_for(rec):
    return SCENARIO_MAX_DROP.get(rec.get("scenario"), MAX_DROP)


def warn(msg):
    print(f"regress-check: WARNING: {msg}", file=sys.stderr)


def load_records(path, *, required):
    """Return the record list, or None for a soft skip on the baseline."""
    try:
        with open(path, encoding="utf-8") as f:
            report = json.load(f)
        records = report["results"]
        if not isinstance(records, list):
            raise TypeError("results is not a list")
    except (OSError, ValueError, KeyError, TypeError) as e:
        if required:
            print(f"regress-check: FAIL: cannot read {path}: {e}", file=sys.stderr)
            sys.exit(1)
        warn(f"cannot read baseline {path} ({e}); skipping regression gate")
        return None
    return records


def identity(rec):
    # Older baselines predate some axes; .get (with the axis default
    # where one exists) keeps them matchable against fresh records.
    return tuple(rec.get(key, AXIS_DEFAULTS.get(key)) for key in MATCH_KEYS)


def main(baseline_path, fresh_path):
    fresh = load_records(fresh_path, required=True)
    baseline = load_records(baseline_path, required=False)
    if baseline is None:
        # Soft skip by design, but loudly: a silently-vanished baseline
        # artifact would disable this gate forever without anyone
        # noticing, so the skip has to be unmissable in the CI log.
        banner = "!" * 64
        for line in (
            banner,
            "!! regress-check: SKIPPED — NO BASELINE TO COMPARE AGAINST",
            "!! Throughput regressions are NOT being gated on this run.",
            "!! Expected on the first run; otherwise check the artifact",
            "!! download step for this pipeline.",
            banner,
        ):
            print(line, file=sys.stderr)
        print("regress-check: SKIP — no baseline to compare against")
        return 0

    base_by_id = {}
    for rec in baseline:
        base_by_id.setdefault(identity(rec), rec)

    compared = skipped = 0
    regressions = []
    worst_by_scenario = {}
    for rec in fresh:
        base = base_by_id.get(identity(rec))
        before = base.get("workload_ops_per_sec", 0) if base else 0
        after = rec.get("workload_ops_per_sec", 0)
        if (
            base is None
            or not isinstance(before, (int, float))
            or not isinstance(after, (int, float))
            or before <= 0
        ):
            skipped += 1
            continue
        compared += 1
        drop = 1.0 - after / before
        allowed = max_drop_for(rec)
        scenario = rec.get("scenario", "?")
        worst = worst_by_scenario.get(scenario)
        if worst is None or drop > worst[0]:
            worst_by_scenario[scenario] = (drop, allowed)
        if drop > allowed:
            key = ", ".join(f"{k}={v}" for k, v in zip(MATCH_KEYS, identity(rec)))
            regressions.append(
                f"  {key}: {before:.0f} -> {after:.0f} ops/s "
                f"({drop:.0%} drop, allowed {allowed:.0%})"
            )

    # Observed-vs-threshold margins, printed win or lose: several CI runs
    # of these lines are the evidence base for tightening a scenario's
    # threshold (a consistently wide margin means headroom).
    for scenario in sorted(worst_by_scenario):
        drop, allowed = worst_by_scenario[scenario]
        print(
            f"regress-check: margin {scenario}: worst drop {drop:+.1%} vs "
            f"allowed {allowed:.0%} (margin {allowed - drop:.1%})"
        )

    if regressions:
        print(
            f"regress-check: FAIL — {len(regressions)} record(s) dropped more "
            f"than their scenario's threshold vs baseline:",
            file=sys.stderr,
        )
        for line in regressions:
            print(line, file=sys.stderr)
        return 1

    print(
        f"regress-check: OK — {compared} records within their scenario "
        f"thresholds (default {MAX_DROP:.0%}; {skipped} skipped: unmatched "
        f"or zero baseline)"
    )
    return 0


if __name__ == "__main__":
    if len(sys.argv) != 3:
        print(
            "usage: check_ablation_regress.py BASELINE FRESH",
            file=sys.stderr,
        )
        sys.exit(2)
    sys.exit(main(sys.argv[1], sys.argv[2]))
