#!/usr/bin/env python3
"""Schema sanity check for BENCH_ablation.json (CI: `make schema-check`).

The ablation bench hand-rolls its JSON (the offline build has no serde),
so a silently-broken recorder could upload garbage artifacts forever.
This gate pins the contract:

* top-level keys: bench / structure / config / results;
* config carries every scale knob the sweeps are keyed on;
* every record carries the full field set — including the scale-layer
  `shards` / `refresh_us` / `daemon_rounds` fields added in PR 4, the
  multi-reactor `reactors` / `pipeline_depth` fields, the scan-mix
  `scan_frac` / `scan_span` axes, and the growth-phase
  `initial_buckets` / `final_buckets` / `migration_quanta` /
  `growth_windows` fields — with finite, non-negative numerics
  (NaN/Infinity literals are rejected at parse time), `reactor_scale`
  records carry both reactor axes >= 1, and `scan_scale` records carry
  a positive scan fraction and span;
* `resize_scale` records describe a real growth phase — a positive
  starting bucket count, a final count at least as large, a non-empty
  per-window throughput curve of finite positive rates, and the
  collapse gate itself: no window below 50% of the median window
  (the acceptance bar for incremental migration — a stop-the-world
  rehash flatlines a window and fails here);
* at least one record actually measured something (positive workload
  throughput), so an all-zero report can't slip through.

Stdlib only. Exit 0 on success, 1 with a pointed message otherwise.
"""

import json
import math
import sys

TOP_KEYS = {"bench", "structure", "config", "results"}
CONFIG_KEYS = {
    "initial",
    "secs",
    "runs",
    "warmup",
    "workload_threads",
    "size_heavy_threads",
    "staleness_ms",
    "seed",
}
RECORD_KEYS = {
    "scenario",
    "policy",
    "mix",
    "size_threads",
    "size_call",
    "shards",
    "key_dist",
    "refresh_us",
    "workload_ops_per_sec",
    "size_ops_per_sec",
    "arbiter_rounds",
    "arbiter_adoptions",
    "arbiter_recent_hits",
    "daemon_rounds",
    "daemon_stalls",
    "fallbacks",
    "retry_budget",
    "per_shard_sheds",
    "reactors",
    "pipeline_depth",
    "scan_frac",
    "scan_span",
    "initial_buckets",
    "final_buckets",
    "migration_quanta",
    "growth_windows",
}
THROUGHPUT_KEYS = ("workload_ops_per_sec", "size_ops_per_sec")
COUNTER_KEYS = (
    "size_threads",
    "shards",
    "refresh_us",
    "arbiter_rounds",
    "arbiter_adoptions",
    "arbiter_recent_hits",
    "daemon_rounds",
    "daemon_stalls",
    "fallbacks",
    "retry_budget",
    "per_shard_sheds",
    "reactors",
    "pipeline_depth",
    "scan_span",
    "initial_buckets",
    "final_buckets",
    "migration_quanta",
)
# Fraction of the median window a growth-phase window may dip to before
# the run counts as a throughput collapse (the issue's acceptance bar).
COLLAPSE_FLOOR = 0.5
SCENARIOS = {
    "periodic-size",
    "size-heavy",
    "scale",
    "shard_scale",
    "reactor_scale",
    "scan_scale",
    "resize_scale",
}
POLICIES = {"baseline", "linearizable", "naive", "lock", "handshake", "optimistic"}


def valid_key_dist(value):
    """`uniform`, or `zipf:<theta>` with a finite float theta in (0, 1) —
    the exact grammar of the Rust `KeyDist::parse`."""
    if value == "uniform":
        return True
    if not isinstance(value, str) or not value.startswith("zipf:"):
        return False
    try:
        theta = float(value[len("zipf:"):])
    except ValueError:
        return False
    return math.isfinite(theta) and 0.0 < theta < 1.0


def fail(msg):
    print(f"schema-check: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main(path):
    try:
        with open(path, encoding="utf-8") as f:
            report = json.load(
                f,
                parse_constant=lambda name: fail(
                    f"non-finite constant {name!r} in the report"
                ),
            )
    except OSError as e:
        fail(f"cannot read {path}: {e}")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")

    if missing := TOP_KEYS - report.keys():
        fail(f"missing top-level keys: {sorted(missing)}")
    if missing := CONFIG_KEYS - report["config"].keys():
        fail(f"missing config keys: {sorted(missing)}")

    records = report["results"]
    if not isinstance(records, list) or not records:
        fail("results must be a non-empty list")

    for i, rec in enumerate(records):
        where = f"results[{i}]"
        if missing := RECORD_KEYS - rec.keys():
            fail(f"{where} missing keys: {sorted(missing)}")
        if rec["scenario"] not in SCENARIOS:
            fail(f"{where} unknown scenario {rec['scenario']!r}")
        if rec["policy"] not in POLICIES:
            fail(f"{where} unknown policy {rec['policy']!r}")
        if not valid_key_dist(rec["key_dist"]):
            fail(f"{where} bad key_dist {rec['key_dist']!r}")
        for key in THROUGHPUT_KEYS:
            v = rec[key]
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                fail(f"{where}.{key} is not numeric: {v!r}")
            if not math.isfinite(v):
                fail(f"{where}.{key} is not finite: {v!r}")
            if v < 0:
                fail(f"{where}.{key} is negative: {v!r}")
        for key in COUNTER_KEYS:
            v = rec[key]
            if not isinstance(v, int) or isinstance(v, bool) or v < 0:
                fail(f"{where}.{key} must be a non-negative integer, got {v!r}")
        frac = rec["scan_frac"]
        if not isinstance(frac, (int, float)) or isinstance(frac, bool):
            fail(f"{where}.scan_frac is not numeric: {frac!r}")
        if not math.isfinite(frac) or not 0.0 <= frac <= 1.0:
            fail(f"{where}.scan_frac must be a finite fraction in [0, 1], got {frac!r}")
        if rec["scenario"] == "reactor_scale":
            # The multi-reactor sweep's own axes: a record claiming the
            # scenario with no reactors (or a zero pipeline) is the
            # recorder misfiling another scenario's row.
            for key in ("reactors", "pipeline_depth"):
                if rec[key] < 1:
                    fail(f"{where}.{key} must be >= 1 in reactor_scale, got {rec[key]!r}")
        if rec["scenario"] == "scan_scale":
            # The scan-mix sweep must actually issue scans: a zero
            # fraction or span is another scenario's row misfiled.
            if not frac > 0.0:
                fail(f"{where}.scan_frac must be > 0 in scan_scale, got {frac!r}")
            if rec["scan_span"] < 1:
                fail(
                    f"{where}.scan_span must be >= 1 in scan_scale, "
                    f"got {rec['scan_span']!r}"
                )
        windows = rec["growth_windows"]
        if not isinstance(windows, list):
            fail(f"{where}.growth_windows must be a list, got {windows!r}")
        for j, v in enumerate(windows):
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                fail(f"{where}.growth_windows[{j}] is not numeric: {v!r}")
            if not math.isfinite(v) or v < 0:
                fail(
                    f"{where}.growth_windows[{j}] must be finite and "
                    f"non-negative, got {v!r}"
                )
        if rec["scenario"] == "resize_scale":
            # The growth sweep must describe a real growth phase: the
            # table started somewhere, ended at least as large, and the
            # window curve is populated with real rates.
            if rec["initial_buckets"] < 1:
                fail(
                    f"{where}.initial_buckets must be >= 1 in resize_scale, "
                    f"got {rec['initial_buckets']!r}"
                )
            if rec["final_buckets"] < rec["initial_buckets"]:
                fail(
                    f"{where}.final_buckets must be >= initial_buckets in "
                    f"resize_scale, got {rec['final_buckets']!r} < "
                    f"{rec['initial_buckets']!r}"
                )
            if not windows:
                fail(f"{where}.growth_windows must be non-empty in resize_scale")
            if min(windows) <= 0.0:
                fail(f"{where}.growth_windows must all be positive in resize_scale")
            # The collapse gate: incremental migration spreads the debt,
            # so no single window may crater against the run's median.
            ordered = sorted(windows)
            median = ordered[len(ordered) // 2]
            floor = COLLAPSE_FLOOR * median
            worst = min(windows)
            if worst < floor:
                fail(
                    f"{where} growth-phase throughput collapse: worst window "
                    f"{worst:.1f} ops/s < {COLLAPSE_FLOOR:.0%} of median "
                    f"{median:.1f} ops/s (floor {floor:.1f})"
                )
            print(
                f"schema-check: resize_scale[{rec['initial_buckets']} -> "
                f"{rec['final_buckets']} buckets] worst window {worst:.1f} vs "
                f"floor {floor:.1f} ops/s (margin {worst - floor:+.1f})"
            )

    if not any(rec["workload_ops_per_sec"] > 0 for rec in records):
        fail("no record measured positive workload throughput (dead recorder?)")

    scenarios = sorted({rec["scenario"] for rec in records})
    print(
        f"schema-check: OK — {len(records)} records, scenarios {scenarios}, "
        f"structure {report['structure']!r}"
    )


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "BENCH_ablation.json")
