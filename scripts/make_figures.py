#!/usr/bin/env python3
"""Render artifact figures from BENCH_ablation.json (`make artifacts`).

Produces, stdlib-only (the CI artifact flow must not need matplotlib):

* `ablation_policies.svg` — horizontal bar chart of workload throughput
  per size policy, one facet per workload mix (periodic-size scenario).
  Single measure -> single hue; every bar carries a direct value label
  (the fill is deliberately light, so labels do the precise reading) and
  identity lives in the row labels, never in color.
* `ablation_summary.txt` — the full record table, the figure's
  text/table view.

Usage: make_figures.py BENCH_ablation.json OUTDIR
"""

import json
import sys

# Chart tokens (light surface; values from a validated palette).
SURFACE = "#fcfcfb"
INK = "#0b0b0b"
INK_MUTED = "#52514e"
GRID = "#e4e3df"
BAR = "#2a78d6"
FONT = "font-family='system-ui, -apple-system, Segoe UI, sans-serif'"

LABEL_W, BAR_MAX_W, BAR_H, BAR_GAP = 120, 380, 18, 8
PAD, VALUE_W = 16, 86
FACET_TITLE_H, FACET_GAP = 34, 18


def fmt_rate(v):
    for cut, suffix in ((1e9, "G"), (1e6, "M"), (1e3, "k")):
        if v >= cut:
            return f"{v / cut:.2f}{suffix} ops/s"
    return f"{v:.0f} ops/s"


def rounded_bar(x, y, w, h, r=4):
    """Bar with a flat baseline edge and a 4px-rounded data end."""
    if w <= r:
        return f"M{x},{y} h{max(w, 1)} v{h} h-{max(w, 1)} z"
    return (
        f"M{x},{y} h{w - r} a{r},{r} 0 0 1 {r},{r} v{h - 2 * r} "
        f"a{r},{r} 0 0 1 -{r},{r} h-{w - r} z"
    )


def facet(rows, title, y0, scale_max, out):
    out.append(
        f"<text x='{PAD}' y='{y0 + 14}' {FONT} font-size='13' font-weight='600' "
        f"fill='{INK}'>{title}</text>"
    )
    y = y0 + FACET_TITLE_H
    x0 = PAD + LABEL_W
    # Recessive baseline, no box.
    height = len(rows) * (BAR_H + BAR_GAP) - BAR_GAP
    out.append(
        f"<line x1='{x0}' y1='{y - 4}' x2='{x0}' y2='{y + height + 4}' "
        f"stroke='{GRID}' stroke-width='1'/>"
    )
    for policy, value in rows:
        w = 0 if scale_max <= 0 else round(BAR_MAX_W * value / scale_max)
        cy = y + BAR_H / 2 + 4
        out.append(
            f"<text x='{x0 - 8}' y='{cy}' {FONT} font-size='12' fill='{INK}' "
            f"text-anchor='end'>{policy}</text>"
        )
        out.append(f"<path d='{rounded_bar(x0, y, w, BAR_H)}' fill='{BAR}'/>")
        out.append(
            f"<text x='{x0 + w + 8}' y='{cy}' {FONT} font-size='11' "
            f"fill='{INK_MUTED}'>{fmt_rate(value)}</text>"
        )
        y += BAR_H + BAR_GAP
    return y


def render_svg(report):
    records = [r for r in report["results"] if r["scenario"] == "periodic-size"]
    mixes = sorted({r["mix"] for r in records})
    if not records:
        return None
    scale_max = max(r["workload_ops_per_sec"] for r in records)
    width = PAD + LABEL_W + BAR_MAX_W + VALUE_W + PAD
    body, y = [], PAD + 22
    body.append(
        f"<text x='{PAD}' y='{PAD + 8}' {FONT} font-size='14' font-weight='600' "
        f"fill='{INK}'>Workload throughput by size policy "
        f"({report['structure']}, smoke scale)</text>"
    )
    for mix in mixes:
        rows = [
            (r["policy"], r["workload_ops_per_sec"])
            for r in records
            if r["mix"] == mix
        ]
        y = facet(rows, f"{mix} mix", y, scale_max, body) + FACET_GAP
    height = y + PAD - FACET_GAP
    return (
        f"<svg xmlns='http://www.w3.org/2000/svg' width='{width}' "
        f"height='{height}' viewBox='0 0 {width} {height}'>"
        f"<rect width='{width}' height='{height}' fill='{SURFACE}'/>"
        + "".join(body)
        + "</svg>\n"
    )


def render_table(report):
    cols = (
        "scenario",
        "policy",
        "mix",
        "size_call",
        "size_threads",
        "shards",
        "refresh_us",
        "workload_ops_per_sec",
        "size_ops_per_sec",
        "daemon_rounds",
    )
    rows = [cols] + [
        tuple(str(round(r[c]) if isinstance(r[c], float) else r[c]) for c in cols)
        for r in report["results"]
    ]
    widths = [max(len(row[i]) for row in rows) for i in range(len(cols))]
    lines = ["  ".join(cell.ljust(w) for cell, w in zip(row, widths)) for row in rows]
    lines.insert(1, "  ".join("-" * w for w in widths))
    return "\n".join(lines) + "\n"


def main(path, outdir):
    with open(path, encoding="utf-8") as f:
        report = json.load(f)
    wrote = []
    svg = render_svg(report)
    if svg is not None:
        with open(f"{outdir}/ablation_policies.svg", "w", encoding="utf-8") as f:
            f.write(svg)
        wrote.append("ablation_policies.svg")
    with open(f"{outdir}/ablation_summary.txt", "w", encoding="utf-8") as f:
        f.write(render_table(report))
    wrote.append("ablation_summary.txt")
    print(f"make_figures: wrote {', '.join(wrote)} to {outdir}/")


if __name__ == "__main__":
    main(sys.argv[1], sys.argv[2])
