#!/usr/bin/env bash
# Boot the reactor server and drive the full protocol, including an
# overload burst that must observe ERR OVERLOAD. `make server-smoke`
# wraps this whole script in `timeout 120`, so a wedged reactor (or a
# self-test deadlock) fails the CI job loudly instead of hanging it.
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=target/release/examples/kv_server
[ -x "$BIN" ] || { echo "server-smoke: $BIN missing (run make build)"; exit 1; }

echo "== --help must exit 0 without binding a socket =="
"$BIN" --help >/dev/null

echo "== self-test mode (reactor burst, swarm, refresher-derived staleness) =="
"$BIN" --refresh-ms 5

echo "== served mode: protocol + admission + pipelining over TCP (2 reactors) =="
LOG=$(mktemp)
"$BIN" --listen 127.0.0.1:0 --size-shards 2 --refresh-ms 5 --workers 4 \
  --reactors 2 --admission-high 64 --admission-low 32 >"$LOG" 2>&1 &
SERVER_PID=$!
trap 'kill "$SERVER_PID" 2>/dev/null || true; rm -f "$LOG"' EXIT

# The server prints its real (ephemeral) address; wait for it.
ADDR=""
for _ in $(seq 1 100); do
  ADDR=$(sed -n 's/^kv_server listening on \([0-9.]*:[0-9]*\).*/\1/p' "$LOG" | head -n1)
  [ -n "$ADDR" ] && break
  kill -0 "$SERVER_PID" 2>/dev/null || { echo "server died at boot:"; cat "$LOG"; exit 1; }
  sleep 0.1
done
[ -n "$ADDR" ] || { echo "server never reported its address:"; cat "$LOG"; exit 1; }
echo "server up at $ADDR"

python3 scripts/smoke_client.py "$ADDR"

kill "$SERVER_PID"
wait "$SERVER_PID" 2>/dev/null || true
echo "server-smoke OK"
