#!/usr/bin/env python3
"""Protocol smoke client for `make server-smoke` (CI's server gate).

Drives a live kv_server over TCP: PUT/DEL/HAS, the dictionary endpoints
(PUT k v / GET / SCAN / COUNT, including multi-line END-terminated scan
replies), all three SIZE flavors, STATS, malformed input — an overload
burst that MUST observe `ERR OVERLOAD` (the server under test runs with
--admission-high 64 --admission-low 32) while `SIZE?` keeps answering
AND a mid-overload SCAN still gets its full reply (range reads are
never shed), followed by a drain that must readmit — and pipelined
bursts (many commands in one TCP segment against the 2-reactor server,
replies read back in strict order, multi-line SCAN blocks holding their
place in the stream). Stdlib only; exits non-zero with a pointed
message on the first broken expectation.
"""

import socket
import sys

HIGH, LOW = 64, 32  # must match the watermarks server_smoke.sh passes


class Client:
    def __init__(self, addr):
        host, port = addr.rsplit(":", 1)
        self.sock = socket.create_connection((host, int(port)), timeout=30)
        self.reader = self.sock.makefile("r", encoding="ascii", newline="\n")

    def cmd(self, line):
        self.sock.sendall((line + "\n").encode("ascii"))
        reply = self.reader.readline()
        if not reply:
            raise AssertionError(f"server closed the connection after {line!r}")
        return reply.strip()

    def read_scan(self):
        """Read one SCAN reply: `k v` lines until the `END n` terminator.
        Returns the (key, value) pairs; checks the trailer count."""
        pairs = []
        while True:
            line = self.reader.readline()
            if not line:
                raise AssertionError("server closed mid-scan")
            line = line.strip()
            if line.startswith("ERR") and not pairs:
                raise AssertionError(f"SCAN answered {line!r}")
            if line.startswith("END "):
                expect(int(line[4:]), len(pairs), "SCAN terminator count")
                return pairs
            k, v = line.split(" ", 1)
            pairs.append((int(k), int(v)))

    def scan(self, lo, hi):
        self.sock.sendall(f"SCAN {lo} {hi}\n".encode("ascii"))
        return self.read_scan()


def expect(got, want, what):
    if got != want:
        raise AssertionError(f"{what}: got {got!r}, wanted {want!r}")


def parse_stats(line):
    stats = {}
    for pair in line.split():
        key, value = pair.split("=", 1)
        stats[key] = int(value)
    return stats


def main(addr):
    c = Client(addr)
    probe = Client(addr)  # separate connection for mid-overload probes

    # Basic protocol round-trips.
    expect(c.cmd("PUT 1"), "1", "fresh PUT")
    expect(c.cmd("PUT 1"), "0", "duplicate PUT")
    expect(c.cmd("HAS 1"), "1", "HAS after PUT")
    expect(c.cmd("DEL 1"), "1", "DEL")
    expect(c.cmd("HAS 1"), "0", "HAS after DEL")
    expect(c.cmd("SIZE"), "0", "exact SIZE on empty store")

    # Malformed input answers ERR without killing the connection.
    assert c.cmd("SIZE~ bogus").startswith("ERR"), "bad staleness must ERR"
    assert c.cmd("NOPE 1").startswith("ERR"), "unknown command must ERR"
    expect(c.cmd("HAS 1"), "0", "connection survives bad commands")

    # Dictionary + range endpoints (cleaned up before the overload burst
    # so the admission arithmetic below stays exact).
    expect(c.cmd("PUT 5 41"), "1", "fresh PUT with a value")
    expect(c.cmd("GET 5"), "41", "GET round-trips the value")
    expect(c.cmd("PUT 5 42"), "0", "value overwrite reports 0")
    expect(c.cmd("GET 5"), "42", "GET sees the overwrite")
    expect(c.cmd("GET 6"), "NIL", "GET on a missing key")
    expect(c.scan(1, 9), [(5, 42)], "SCAN returns the key/value pair")
    expect(c.cmd("COUNT 1 9"), "1", "COUNT agrees with SCAN")
    expect(c.cmd("SCAN 9 1"), "END 0", "inverted range is empty, not an error")
    assert c.cmd("SCAN 1").startswith("ERR"), "SCAN without a range must ERR"
    assert c.cmd("COUNT 1 x").startswith("ERR"), "bad COUNT bound must ERR"
    expect(c.cmd("DEL 5"), "1", "dictionary cleanup")

    # Overload burst: push past the high watermark; sheds must appear.
    admitted, sheds = 0, 0
    for k in range(3 * HIGH):
        reply = c.cmd(f"PUT {k}")
        if reply == "ERR OVERLOAD":
            sheds += 1
            if sheds == 1:
                # Mid-shed, the cheap probe keeps answering on another
                # connection, and STATS reports the shedding state.
                estimate = int(probe.cmd("SIZE?"))
                assert estimate >= HIGH, f"shed below high watermark: {estimate}"
                stats = parse_stats(probe.cmd("STATS"))
                expect(stats["admitting"], 0, "STATS admitting during shed")
                # Range reads are never shed: a SCAN launched in the
                # middle of the overload must answer in full — exactly
                # the HIGH admitted keys, all holding the default value.
                pairs = probe.scan(0, 3 * HIGH)
                expect(len(pairs), HIGH, "mid-overload SCAN answers in full")
                assert all(v == 0 for _, v in pairs), "valueless PUTs scan as 0"
                expect(
                    probe.cmd(f"COUNT 0 {3 * HIGH}"),
                    str(HIGH),
                    "mid-overload COUNT",
                )
        elif reply == "1":
            admitted += 1
        else:
            raise AssertionError(f"unexpected PUT reply {reply!r}")
    assert sheds > 0, "overload burst never observed ERR OVERLOAD"
    expect(admitted, HIGH, "admitted PUTs up to the high watermark")

    stats = parse_stats(probe.cmd("STATS"))
    assert stats["shed"] == sheds, f"STATS shed={stats['shed']} != {sheds}"

    # Size endpoints keep working under shed (reads are never shed).
    assert int(c.cmd("SIZE~ 500")) >= 0, "SIZE~ during shed"
    assert int(c.cmd("SIZE?")) >= 0, "SIZE? during shed"

    # Drain below the low watermark: PUTs readmit (hysteresis).
    for k in range(3 * HIGH):
        reply = c.cmd(f"DEL {k}")
        assert reply in ("0", "1"), f"DEL must never shed, got {reply!r}"
    expect(c.cmd("PUT 9999"), "1", "PUT readmitted after drain")
    stats = parse_stats(probe.cmd("STATS"))
    expect(stats["admitting"], 1, "STATS admitting after drain")
    assert stats["daemon_rounds"] > 0, "refresher daemon drove no rounds"

    # Pipelined burst: 96 commands in one TCP segment on a fresh
    # connection; the 2-reactor server batches them into handler jobs
    # and coalesces the replies, which must come back in strict order
    # (PUT/HAS/DEL over fresh keys all answer "1").
    k = 32
    pipe = Client(addr)
    wire = "".join(
        f"{verb} {20000 + i}\n" for verb in ("PUT", "HAS", "DEL") for i in range(k)
    )
    pipe.sock.sendall(wire.encode("ascii"))
    for phase in ("PUT", "HAS", "DEL"):
        for i in range(k):
            reply = pipe.reader.readline().strip()
            expect(reply, "1", f"pipelined {phase} #{i} (reply order)")
    stats = parse_stats(probe.cmd("STATS"))
    expect(stats["reactors"], 2, "STATS reactor-shard count")

    # Scan-mixed pipelined burst: a multi-line SCAN reply must hold its
    # place in the coalesced reply stream, byte-for-byte in order.
    n = 16
    pipe2 = Client(addr)
    wire = "".join(f"PUT {30000 + i} {i}\n" for i in range(n))
    wire += f"SCAN 30000 {30000 + n - 1}\n"
    wire += f"COUNT 30000 {30000 + n - 1}\n"
    wire += "HAS 30005\n"
    wire += "".join(f"DEL {30000 + i}\n" for i in range(n))
    pipe2.sock.sendall(wire.encode("ascii"))
    for i in range(n):
        expect(pipe2.reader.readline().strip(), "1", f"pipelined PUT #{i}")
    expect(
        pipe2.read_scan(),
        [(30000 + i, i) for i in range(n)],
        "pipelined SCAN block (values and order)",
    )
    expect(pipe2.reader.readline().strip(), str(n), "pipelined COUNT")
    expect(pipe2.reader.readline().strip(), "1", "pipelined HAS")
    for i in range(n):
        expect(pipe2.reader.readline().strip(), "1", f"pipelined DEL #{i}")

    expect(c.cmd("SIZE"), "1", "exact SIZE after drain")
    # QUIT has no reply; the server closes the connection.
    c.sock.sendall(b"QUIT\n")
    expect(c.reader.readline(), "", "QUIT must close without a reply")
    print(
        f"smoke client OK: {admitted} admitted, {sheds} shed, "
        f"final stats {stats}"
    )


if __name__ == "__main__":
    main(sys.argv[1])
